"""Networked transparency deployment demo: one owner, two verifiers, TCP.

The full deployment story of the transparency fabric, end to end::

    PYTHONPATH=src python examples/serve_queries.py [--queries 4] [--dir D]

The driver (this process) orchestrates three child processes that talk to
each other **over real sockets** (`repro.net`, protocol.md §10) — no
in-process object crosses a trust boundary, only signed bytes on the wire:

* an **owner** that opens a *durable* transparency log
  (``TransparencyLog.open``), publishes the commitment manifest as leaf 0,
  proves a queue of LDBC queries through a ``ProofService``, and runs a
  ``NetServer`` serving its Ed25519-signed gossip head, the manifest,
  inclusion/consistency proofs, and finished ``ProofBundle``\\ s;
* **two verifiers** that each run their own ``NetServer`` (for
  verifier-to-verifier gossip) and a ``PeerClient`` toward the owner —
  through a deterministic in-process ``FaultProxy`` that drops and
  truncates frames to prove the retry/backoff path — bootstrap their
  entire trust root from fetched bytes, verify every bundle, advance
  their pinned head across a manifest revision only on a valid
  consistency proof, and cross-gossip their heads over TCP.

Mid-stream the driver **kills the owner with SIGKILL**, appends a torn
half-record to the log file (what a crash during an unsynced write leaves
behind), and restarts the owner on a fresh port: the reopened log
truncates the torn tail, the owner resumes at the first unproven query,
and the verifiers — whose circuit breakers opened while the owner was
dead — keep serving from their last pinned head, re-resolve the port, and
reconnect.  Finally the driver plays a malicious owner: it forks the log
history, signs the forked head with the REAL origin key, and pushes it to
both verifiers over their gossip sockets — both must answer with an
``RESP_EQUIVOCATION`` frame carrying the ``EquivocationError`` evidence.

The driver asserts all of it: recovery happened, every bundle verified in
both verifier processes, heads advanced exactly once, no process hung
past its timeout budget, and equivocation was detected by both peers.
"""
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
sys.path.insert(0, str(SRC))

import argparse
import json
import os
import signal
import subprocess
import tempfile
import threading
import time

from repro.core import gossip
from repro.core import prover as pv
from repro.core.ed25519 import SigningKey
from repro.core.session import ZKGraphSession
from repro.core.transparency import InclusionProof, TransparencyLog
from repro.graphdb import ldbc
from repro.net import framing
from repro.net.faults import FaultProxy
from repro.net.peer import PeerClient, PeerUnavailable
from repro.net.server import NetServer
from repro.serve import ProofService

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)
ORIGIN = "zkgraph-serve-log"
# the log operator's Ed25519 identity.  The demo driver knowingly holds the
# signing half so it can play a MALICIOUS owner in the final act — which is
# exactly the threat gossip exists to catch: a correctly-signed but
# equivocating head.  Verifiers pin only KEY.pub.
KEY = SigningKey.from_secret(b"zkgraph-demo-origin-key")
TIMEOUT = float(os.environ.get("ZKGRAPH_DEMO_TIMEOUT", "900"))

# deterministic fault scripts, one per verifier: the first frames of each
# verifier's owner-link get dropped/truncated/stalled, so bootstrap itself
# exercises retry-with-backoff and typed frame errors on every demo run
FAULT_SCRIPTS = {
    "v1": ["drop", "pass", "truncate", "pass", "drop"],
    "v2": ["pass", "drop", "pass", "truncate"],
}


def query_queue(db, n):
    import numpy as np
    rng = np.random.default_rng(41)
    qs = []
    for i in range(n):
        kind = ["IS3", "IS5", "IC13"][i % 3]
        if kind == "IS3":
            qs.append((kind, dict(person=int(rng.integers(1, db.n_nodes)))))
        elif kind == "IS5":
            qs.append((kind, dict(message=(1 << 20) + int(
                rng.integers(0, 32)))))
        else:
            qs.append((kind, dict(person1=int(rng.integers(1, 8)),
                                  person2=int(rng.integers(9, 24)))))
    return qs


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------
def _strip_timings(raw: bytes) -> bytes:
    """Re-encode bundle bytes with per-step prover timings zeroed: timings
    are host-side telemetry carried in the wire format, and the only field
    where a batched and a solo prove may legitimately differ."""
    from repro.core.session import ProofBundle
    bundle = ProofBundle.from_bytes(raw)
    for sp in bundle.steps:
        sp.proof.timings = {}
    return bundle.to_bytes()


def atomic_write(path: Path, data: bytes) -> None:
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)       # readers only ever see complete files


def wait_for(path: Path, deadline: float) -> bytes:
    while time.time() < deadline:
        if path.exists():
            return path.read_bytes()
        time.sleep(0.1)
    raise TimeoutError(f"timed out waiting for {path}")


def read_port(d: Path, name: str, deadline: float) -> int:
    return int(wait_for(d / f"{name}.port", deadline).decode())


def _cfg_args(cfg: pv.ProverConfig, n_knows: int, n_persons: int) -> list:
    return ["--blowup", str(cfg.blowup), "--n-queries", str(cfg.n_queries),
            "--fri-final-size", str(cfg.fri_final_size),
            "--n-knows", str(n_knows), "--n-persons", str(n_persons)]


def _build(args):
    cfg = pv.ProverConfig(blowup=args.blowup, n_queries=args.n_queries,
                          fri_final_size=args.fri_final_size)
    db = ldbc.generate(n_knows=args.n_knows, n_persons=args.n_persons,
                       seed=3)
    return db, cfg


# ---------------------------------------------------------------------------
# the owner process: a durable log + a ProofService behind a NetServer
# ---------------------------------------------------------------------------
def run_owner(args) -> None:
    d = Path(args.dir)
    db, cfg = _build(args)
    session = ZKGraphSession(db, cfg)
    log = TransparencyLog.open(d / "transparency.log", ORIGIN)
    if log.recovered_bytes:
        print(f"[owner] crash recovery: truncated {log.recovered_bytes} "
              f"torn-tail bytes, {log.size} intact leaves", flush=True)
    raw = session.commitments.to_bytes()
    if log.size == 0:
        _, _, raw = session.publish_to(log)
        print(f"[owner] manifest published: {len(raw)} bytes -> "
              f"log {ORIGIN!r} size {log.size}", flush=True)
    else:
        assert log.entry(0) == raw, "restart re-derived a different manifest"
        print(f"[owner] resumed with {log.size} published leaves", flush=True)
    log.sync()                  # audit disk against memory before serving

    spool = d / "bundles"
    spool.mkdir(exist_ok=True)
    log_lock = threading.Lock()     # server threads vs the revision append

    def on_head(payload):
        with log_lock:
            return (framing.RESP_HEAD, gossip.emit(log, KEY).to_bytes())

    def on_manifest(payload):
        return (framing.RESP_MANIFEST, raw)

    def on_inclusion(payload):
        # payload: the verifier's pinned tree size, so the proof targets
        # exactly the checkpoint that verifier has verified
        size = int.from_bytes(payload, "little") if payload else 1
        with log_lock:
            return (framing.RESP_INCLUSION,
                    log.inclusion_proof(0, size).to_bytes())

    def on_consistency(payload):
        since = int.from_bytes(payload, "little")
        with log_lock:
            return (framing.RESP_CONSISTENCY,
                    gossip.emit(log, KEY, since=since).to_bytes())

    def on_bundle(payload):
        cursor = int.from_bytes(payload, "little")
        path = spool / f"q{cursor}.bin"
        if cursor >= args.queries:
            raise ValueError(f"no query at cursor {cursor}")
        if not path.exists():
            return (framing.RESP_PENDING, b"")
        return (framing.RESP_BUNDLE, path.read_bytes())

    srv = NetServer()
    srv.register(framing.REQ_PING, lambda p: (framing.RESP_PONG, p))
    srv.register(framing.REQ_HEAD, on_head)
    srv.register(framing.REQ_MANIFEST, on_manifest)
    srv.register(framing.REQ_INCLUSION, on_inclusion)
    srv.register(framing.REQ_CONSISTENCY, on_consistency)
    srv.register(framing.REQ_BUNDLE, on_bundle)
    _, port = srv.start()
    atomic_write(d / "owner.port", str(port).encode())
    print(f"[owner] serving on 127.0.0.1:{port}", flush=True)

    pending = [(i, kind, params)
               for i, (kind, params) in enumerate(query_queue(db,
                                                              args.queries))
               if not (spool / f"q{i}.bin").exists()]
    # all unproven queries ride ONE ProofService: same-shaped steps from
    # different queries share lane-batched proves, and each returned bundle
    # is wire-byte-identical to a solo session.prove (spot-checked below)
    if pending:
        with ProofService(session, max_batch=4, flush_interval=0.25) as svc:
            t0 = time.time()
            futs = [(i, kind, svc.submit(kind, params))
                    for i, kind, params in pending]
            for i, kind, fut in futs:
                bundle = fut.result()
                atomic_write(spool / f"q{i}.bin", bundle.to_bytes())
                print(f"[owner] q{i} {kind:5s} spooled at "
                      f"{time.time() - t0:.1f}s ({len(bundle.steps)} ops)",
                      flush=True)
            occupancy = svc.stats()["batch_occupancy"]
        print(f"[owner] served {len(pending)} queries, mean batch "
              f"occupancy {occupancy['mean']:.2f}", flush=True)
        # byte-for-byte spot check: re-prove one serviced query solo and
        # compare wire bytes (timings are telemetry, not proof material)
        i0, kind0, params0 = pending[0]
        serviced = (spool / f"q{i0}.bin").read_bytes()
        solo = session.prove(kind0, params0)
        assert _strip_timings(serviced) == _strip_timings(solo.to_bytes()), \
            "serviced bundle bytes diverged from the solo prover"
        print(f"[owner] q{i0} re-proven solo: bytes identical", flush=True)

    with log_lock:
        if log.size < 2:        # manifest revision: the log must only GROW
            session.publish_to(log)
        head = log.sync()
    stats = session.cache.stats()
    atomic_write(d / "owner.done", json.dumps(dict(
        queries=args.queries, tree_size=head.tree_size,
        keygen_misses=stats["misses"], keygen_hits=stats["hits"]),
        sort_keys=True).encode())
    print(f"[owner] done: log size {head.tree_size}, keygen cache "
          f"{stats['misses']} misses / {stats['hits']} hits; still serving",
          flush=True)
    # stay up serving heads/proofs/bundles until the driver reaps us
    while True:
        time.sleep(0.5)


# ---------------------------------------------------------------------------
# a verifier process: its own gossip server + a fault-proxied owner link
# ---------------------------------------------------------------------------
class OwnerLink:
    """The verifier's resilient path to the owner: resolves the owner's
    current port from the work dir, optionally routes through a
    deterministic FaultProxy, and retries through PeerUnavailable — which
    is exactly what an owner SIGKILL and restart on a new port looks like
    from this side.  Every wait is bounded by the shared deadline."""

    def __init__(self, d: Path, name: str, deadline: float, faults):
        self.d = d
        self.name = name
        self.deadline = deadline
        self.faults = list(faults)
        self.port = None
        self.proxy = None
        self.client = None

    def _connect(self) -> None:
        port = read_port(self.d, "owner", self.deadline)
        if port == self.port and self.client is not None:
            return
        self.close()
        self.port = port
        target = ("127.0.0.1", port)
        if self.faults:
            # the scripted faults hit this first incarnation of the link;
            # a reconnect after owner restart goes direct
            self.proxy = FaultProxy(target, script=self.faults,
                                    stall_seconds=1.0)
            target = self.proxy.start()
            self.faults = []
        self.client = PeerClient(target, timeout=2.0, retries=3,
                                 backoff=0.05, cooldown=0.3)

    def close(self) -> None:
        if self.client is not None:
            self.client.close()
            self.client = None
        if self.proxy is not None:
            self.proxy.stop()
            self.proxy = None

    def rpc(self, kind: int, payload: bytes = b"",
            fallback=None) -> tuple[int, bytes]:
        """One request, surviving owner death: on PeerUnavailable the link
        re-resolves the port (the restarted owner binds a new one) and
        tries again until the deadline.  ``fallback`` is called once per
        outage — the hook verifiers use to report they keep serving from
        the pinned head instead of wedging."""
        reported = False
        while time.time() < self.deadline:
            self._connect()
            try:
                return self.client.request(kind, payload)
            except PeerUnavailable:
                if fallback is not None and not reported:
                    fallback()
                    reported = True
                # stale port file?  the restarted owner rewrites it
                self.port = None
                time.sleep(0.2)
        raise TimeoutError(f"[{self.name}] owner unreachable past deadline")


def run_verifier(args) -> None:
    d = Path(args.dir)
    name = args.name
    deadline = time.time() + TIMEOUT
    # proof policy only — a verifier holds NO database, just the trust root
    cfg = pv.ProverConfig(blowup=args.blowup, n_queries=args.n_queries,
                          fri_final_size=args.fri_final_size)

    peer = gossip.GossipPeer(ORIGIN, KEY.pub)
    peer_lock = threading.Lock()
    equivocation = {"detected": False, "evidence": ""}
    alarm = threading.Event()

    def on_gossip(payload):
        """Another peer (or the adversary) pushes a head at this verifier:
        verify-and-advance under the lock; an equivocating head answers
        with the alarm frame carrying the evidence."""
        msg = gossip.GossipMessage.from_bytes(payload)
        try:
            with peer_lock:
                advanced = peer.offer(msg)
        except gossip.EquivocationError as e:
            equivocation.update(detected=True, evidence=str(e))
            alarm.set()
            print(f"[{name}] ALARM: {e}", flush=True)
            return (framing.RESP_EQUIVOCATION, str(e).encode("utf-8"))
        return (framing.RESP_ACK, b"advanced" if advanced else b"agreed")

    def on_head(payload):
        with peer_lock:
            return (framing.RESP_HEAD, peer.head_message().to_bytes())

    srv = NetServer()
    srv.register(framing.REQ_PING, lambda p: (framing.RESP_PONG, p))
    srv.register(framing.REQ_GOSSIP, on_gossip)
    srv.register(framing.REQ_HEAD, on_head)
    _, port = srv.start()
    atomic_write(d / f"{name}.port", str(port).encode())

    link = OwnerLink(d, name, deadline,
                     FAULT_SCRIPTS.get(name, []) if args.faults else [])

    def fallback():
        with peer_lock:
            pinned = peer.head.tree_size if peer.head is not None else None
        state = f"serving from pinned head @{pinned}" if pinned is not None \
            else "no head pinned yet"
        print(f"[{name}] owner unreachable; {state}, retrying", flush=True)

    # ---- bootstrap: the whole trust root arrives as frames ---------------
    kind, head_raw = link.rpc(framing.REQ_HEAD, fallback=fallback)
    assert kind == framing.RESP_HEAD, f"expected RESP_HEAD, got {kind:#x}"
    with peer_lock:
        peer.offer(gossip.GossipMessage.from_bytes(head_raw))
        boot_size = peer.pinned.tree_size
    kind, manifest_raw = link.rpc(framing.REQ_MANIFEST, fallback=fallback)
    assert kind == framing.RESP_MANIFEST
    kind, incl_raw = link.rpc(framing.REQ_INCLUSION,
                              int(boot_size).to_bytes(8, "little"),
                              fallback=fallback)
    assert kind == framing.RESP_INCLUSION
    verifier = ZKGraphSession.verifier(
        cfg=cfg, gossip=peer, inclusion=InclusionProof.from_bytes(incl_raw),
        manifest_bytes=manifest_raw)
    print(f"[{name}] trust root bootstrapped over TCP from gossip-pinned "
          f"head @{boot_size}", flush=True)

    # ---- stream the bundles (the owner dies and resumes mid-stream) ------
    results = {}
    for i in range(args.queries):
        while True:
            kind, data = link.rpc(framing.REQ_BUNDLE,
                                  i.to_bytes(8, "little"), fallback=fallback)
            if kind == framing.RESP_BUNDLE:
                break
            assert kind == framing.RESP_PENDING, f"unexpected {kind:#x}"
            if time.time() > deadline:
                raise TimeoutError(f"[{name}] q{i} never arrived")
            time.sleep(0.2)
        results[f"q{i}"] = bool(verifier.verify_bytes(data))
        print(f"[{name}] q{i} verified from {len(data)} bytes: "
              f"{results[f'q{i}']}", flush=True)

    # ---- the owner revised the manifest: advance ONLY on a proof ---------
    advanced = False
    while time.time() < deadline and not advanced:
        kind, head_raw = link.rpc(framing.REQ_HEAD, fallback=fallback)
        assert kind == framing.RESP_HEAD
        msg = gossip.GossipMessage.from_bytes(head_raw)
        with peer_lock:
            if msg.checkpoint.tree_size == peer.pinned.tree_size:
                pass                            # not revised yet
            else:
                try:
                    advanced = peer.offer(msg)
                except gossip.ConsistencyRequired:
                    pass                        # fetch the linking proof
        if advanced:
            break
        if msg.checkpoint.tree_size > boot_size:
            kind, linked = link.rpc(
                framing.REQ_CONSISTENCY,
                int(peer.pinned.tree_size).to_bytes(8, "little"),
                fallback=fallback)
            assert kind == framing.RESP_CONSISTENCY
            with peer_lock:
                advanced = peer.offer(gossip.GossipMessage.from_bytes(linked))
        else:
            time.sleep(0.2)
    print(f"[{name}] head advanced to @{peer.pinned.tree_size} "
          f"(append-only growth proven)", flush=True)
    atomic_write(d / f"{name}.advanced", b"1")

    # ---- verifier <-> verifier gossip over TCP ---------------------------
    other = "v2" if name == "v1" else "v1"
    wait_for(d / f"{other}.advanced", deadline)
    other_client = PeerClient(("127.0.0.1", read_port(d, other, deadline)),
                              timeout=2.0, retries=5, backoff=0.1)
    with peer_lock:
        my_head = peer.head_message().to_bytes()
    kind, verdict = other_client.request(framing.REQ_GOSSIP, my_head)
    other_client.close()
    assert kind == framing.RESP_ACK, \
        f"cross-gossip with {other} raised: {verdict!r}"
    cross = verdict == b"advanced"
    print(f"[{name}] cross-gossip with {other}: heads agree "
          f"({verdict.decode()})", flush=True)

    # ---- the forged fork arrives on OUR server; wait for the alarm -------
    if not alarm.wait(timeout=max(0.0, deadline - time.time())):
        print(f"[{name}] no equivocation push arrived before the deadline",
              flush=True)
    atomic_write(d / f"{name}.done", json.dumps(dict(
        results=results, advanced=bool(advanced), cross_advance=bool(cross),
        equivocation_detected=bool(equivocation["detected"]),
        head=peer.pinned.tree_size), sort_keys=True).encode())
    # stay up until the driver reaps us: the other verifier or the driver
    # may still be talking to our gossip server
    while True:
        time.sleep(0.5)


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------
def _spawn(role: str, d: str, args, extra=()) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, str(Path(__file__).resolve()), "--role", role,
           "--dir", d, "--queries", str(args.queries),
           *(() if args.faults else ("--no-faults",)),
           *_cfg_args(pv.ProverConfig(args.blowup, args.n_queries,
                                      args.fri_final_size), args.n_knows,
                      args.n_persons), *extra]
    return subprocess.Popen(cmd, env=env)


def _wait_done(path: Path, procs, deadline: float) -> dict:
    while time.time() < deadline:
        if path.exists():
            return json.loads(path.read_bytes())
        for p in procs:
            if p.poll() not in (None, 0):
                raise RuntimeError(
                    f"child {p.args[-1]} exited with {p.returncode} "
                    f"before producing {path.name}")
        time.sleep(0.2)
    raise TimeoutError(f"timed out waiting for {path}")


def run_driver(args) -> dict:
    d = Path(args.dir or tempfile.mkdtemp(prefix="zkgraph_demo_"))
    d.mkdir(parents=True, exist_ok=True)
    stale = [p.name for p in (d / "owner.done", d / "v1.done",
                              d / "v2.done", d / "transparency.log")
             if p.exists()]
    if stale:
        raise SystemExit(
            f"[driver] {d} holds artifacts from a previous run ({stale}); "
            f"the demo's waits would satisfy themselves from them without "
            f"exercising anything — use a fresh --dir")
    (d / "bundles").mkdir(exist_ok=True)
    print(f"[driver] work dir: {d}", flush=True)
    deadline = time.time() + TIMEOUT
    children = []
    try:
        for name in ("v1", "v2"):
            children.append(_spawn("verifier", str(d), args,
                                   ("--name", name)))
        owner = _spawn("owner", str(d), args)
        children.append(owner)

        # let the owner prove `kill_after` queries, then pull the plug
        kill_mark = d / "bundles" / f"q{args.kill_after - 1}.bin"
        wait_for(kill_mark, deadline)
        try:
            owner.send_signal(signal.SIGKILL)
        except ProcessLookupError:
            pass                # already exited: restart is a plain resume
        owner.wait()
        (d / "owner.port").unlink(missing_ok=True)   # port died with it
        print(f"[driver] owner SIGKILLed after {args.kill_after} queries",
              flush=True)
        # what a crash mid-write leaves: a torn half-record on the log tail
        with open(d / "transparency.log", "ab") as fh:
            fh.write(b"\x01\x40\x00\x00\x00partial")
        print("[driver] torn half-record appended to the log tail",
              flush=True)

        owner = _spawn("owner", str(d), args)
        children.append(owner)
        owner_summary = _wait_done(d / "owner.done", [owner], deadline)

        # the malicious-owner act: fork the history (different leaf 0),
        # sign the forked head with the REAL origin key, and PUSH it to
        # both verifiers' gossip servers — only after both have advanced,
        # so the fork collides with verified history, not a knowledge gap
        for name in ("v1", "v2"):
            wait_for(d / f"{name}.advanced", deadline)
        client = PeerClient(("127.0.0.1", read_port(d, "owner", deadline)),
                            timeout=2.0, retries=5, backoff=0.1)
        kind, manifest_raw = client.request(framing.REQ_MANIFEST, b"")
        client.close()
        assert kind == framing.RESP_MANIFEST
        fork = TransparencyLog(ORIGIN)
        fork.append(manifest_raw + b"\xff")
        fork.append(manifest_raw)
        forged = gossip.emit(fork, KEY)
        alarms = {}
        for name in ("v1", "v2"):
            client = PeerClient(("127.0.0.1", read_port(d, name, deadline)),
                                timeout=2.0, retries=5, backoff=0.1)
            kind, evidence = client.request(framing.REQ_GOSSIP,
                                            forged.to_bytes())
            client.close()
            alarms[name] = (kind, evidence)
            print(f"[driver] forged (signed!) fork head pushed to {name}: "
                  f"frame {kind:#x}", flush=True)
        for name, (kind, evidence) in alarms.items():
            assert kind == framing.RESP_EQUIVOCATION, \
                f"{name} answered {kind:#x} instead of the alarm frame"
            assert b"equivocation detected" in evidence, evidence

        summaries = {
            name: _wait_done(d / f"{name}.done", children[:2], deadline)
            for name in ("v1", "v2")}
    finally:
        for p in children:
            if p.poll() is None:
                p.kill()

    for name, s in summaries.items():
        assert all(s["results"].values()), f"{name} rejected a bundle: {s}"
        assert s["advanced"] and not s["cross_advance"], s
        assert s["equivocation_detected"] is True, \
            f"{name} missed the equivocation"
    assert owner_summary["tree_size"] == 2
    n_ok = sum(len(s["results"]) for s in summaries.values())
    print(f"[driver] OK: crash-recovered owner served {args.queries} "
          f"queries over TCP; {n_ok} bundle verifications across 2 "
          f"verifier processes; revision advanced by consistency proof; "
          f"forged fork alarmed by both peers", flush=True)
    return dict(owner=owner_summary, **summaries)


def main(argv=None, n_knows=128, n_persons=24, cfg=CFG):
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=["driver", "owner", "verifier"],
                    default="driver")
    ap.add_argument("--dir", default=None)
    ap.add_argument("--name", default="v1")
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--kill-after", type=int, default=2,
                    help="SIGKILL the owner after this many proven queries")
    ap.add_argument("--no-faults", dest="faults", action="store_false",
                    help="disable the deterministic frame-fault injection "
                         "on the verifiers' owner links")
    ap.add_argument("--blowup", type=int, default=cfg.blowup)
    ap.add_argument("--n-queries", type=int, default=cfg.n_queries)
    ap.add_argument("--fri-final-size", type=int, default=cfg.fri_final_size)
    ap.add_argument("--n-knows", type=int, default=n_knows)
    ap.add_argument("--n-persons", type=int, default=n_persons)
    args = ap.parse_args(argv)
    # the kill mark must be a bundle the owner actually produces, or the
    # driver would wait out the whole demo timeout on a short queue
    args.kill_after = max(1, min(args.kill_after, args.queries))
    if args.role == "owner":
        return run_owner(args)
    if args.role == "verifier":
        return run_verifier(args)
    return run_driver(args)


if __name__ == "__main__":
    main()
