"""The parsed query front door, end to end: a GQL-subset text is compiled
to the plan IR, proved, serialized, and verified by a session that holds
only the commitments — the verifier re-compiles the query text itself to
rebuild the expected plan, so prover and verifier agree on nothing beyond
the text and the published commitments.

    PYTHONPATH=src python examples/query_text.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import prover as pv
from repro.core.session import ZKGraphSession
from repro.graphdb import ldbc
from repro.query import QUERY_TEXTS, compile_query, render_plan

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)


def main(n_knows=150, n_persons=32, cfg=CFG, seed=13):
    db = ldbc.generate(n_knows=n_knows, n_persons=n_persons, seed=seed)
    owner = ZKGraphSession(db, cfg)
    verifier = ZKGraphSession.verifier(owner.commitments, cfg)
    names = db.node_props["person"]["firstName"]
    thr = int(np.median(names))

    # -- a query no hand-written plan covers: order predicate + aggregate --
    text = ("MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person) "
            "WHERE f.firstName >= $thr RETURN f.id AS ids")
    plan = compile_query(text)
    print("compiled plan for the filter query:")
    print(render_plan(plan))
    bundle = owner.prove_plan(plan, dict(person=2, thr=thr))
    assert verifier.verify_bytes(bundle.to_bytes())
    print(f"friends of person 2 with firstName >= {thr}: "
          f"{sorted(np.asarray(bundle.result['ids']).tolist())}")

    agg_text = ("MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person) "
                "RETURN min(f.firstName) AS youngest")
    bundle = owner.prove_plan(compile_query(agg_text), dict(person=2))
    assert verifier.verify_bytes(bundle.to_bytes())
    print(f"min firstName among person 2's friends: "
          f"{int(bundle.result['youngest'])} "
          f"(proved by the Aggregate circuit, not asserted by the owner)")

    # -- an LDBC text compiles to the hand-written plan's exact wire bytes --
    qname = "IS5"
    params = dict(message=int(db.tables["comment_hasCreator_person"].src[0]))
    hand = owner.prove(qname, dict(params))
    compiled = owner.prove_plan(compile_query(QUERY_TEXTS[qname],
                                              name=qname), dict(params))
    for b in (hand, compiled):
        for st in b.steps:
            st.proof.timings = {}          # wall-clock metadata only
    assert hand.to_bytes() == compiled.to_bytes()
    print(f"{qname}: compiled text proves to the hand plan's exact "
          f"{len(compiled.to_bytes())} wire bytes")


if __name__ == "__main__":
    main()
