"""Train a ~130M-parameter LM for a few hundred steps on the synthetic
pipeline — exercises the full training substrate (optimizer, remat, ckpt,
deterministic resume) on one host.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 40 --tiny   # CI-sized
"""
import sys
sys.path.insert(0, "src")

import argparse
import os
import time
from dataclasses import replace

import jax

from repro.models.config import ModelConfig, param_count
from repro.models import lm
from repro.train import checkpoint, compression, data
from repro.train import optimizer as opt
from repro.train import train_step as ts

LM_130M = ModelConfig(
    name="repro-130m", n_layers=10, d_model=640, n_heads=10, n_kv=10,
    d_ff=2560, vocab=50048, head_dim=64, norm="rmsnorm", mlp="swiglu",
    remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = LM_130M if not args.tiny else replace(
        LM_130M, n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=512,
        vocab=1024, head_dim=32)
    print(f"{cfg.name}: {param_count(cfg)/1e6:.0f}M params")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=6e-4, warmup_steps=max(10, args.steps // 10),
                           total_steps=args.steps)
    state = opt.init_state(params)
    err = compression.init_error(params)
    step_fn = jax.jit(ts.make_train_step(cfg, ocfg))
    stream = data.TokenStream(data.DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))

    os.makedirs(args.ckpt, exist_ok=True)
    start = checkpoint.latest_step(args.ckpt) or 0
    if start:
        params, state, start, extra = checkpoint.restore(
            args.ckpt, start, params, state)
        stream.load_state_dict(extra["data"])
        print(f"resumed at step {start}")

    first = None
    for step in range(start, args.steps):
        t0 = time.time()
        params, state, err, m = step_fn(params, state, err, next(stream))
        if first is None:
            first = float(m["loss"])
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time()-t0)*1e3:.0f} ms)", flush=True)
        if (step + 1) % 100 == 0:
            checkpoint.save(args.ckpt, step + 1, params, state,
                            extra={"data": stream.state_dict()})
    print(f"loss: {first:.3f} -> {float(m['loss']):.3f}")
    assert float(m["loss"]) < first, "training must reduce loss"


if __name__ == "__main__":
    main()
