"""IC1-style chained query proof: 3-hop friend expansion + name filter +
order-by — the expansion-centric decomposition end to end (paper §III-D),
driven through the declarative plan IR and the session API.

    PYTHONPATH=src python examples/ldbc_ic1.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import ir
from repro.core import prover as pv
from repro.core.session import ZKGraphSession
from repro.graphdb import ldbc

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)


def main(n_knows=150, n_persons=32, cfg=CFG, seed=13):
    db = ldbc.generate(n_knows=n_knows, n_persons=n_persons, seed=seed)
    owner = ZKGraphSession(db, cfg)
    name = int(db.node_props["person"]["firstName"][0])
    params = dict(person=2, firstName=name)

    plan = ir.build_plan("IC1")
    print(f"IC1 plan: {len(plan.nodes)} nodes:")
    for i, node in enumerate(plan.nodes):
        print(f"  [{i}] {type(node).__name__}")

    bundle = owner.prove("IC1", params)
    print(f"executed -> {len(bundle.steps)} chained operator proofs:")
    for rec in bundle.steps:
        shape = {k: v for k, v in rec.shape.items() if k != "n_rows"}
        print(f"  {rec.kind:12s} rows={rec.shape['n_rows']:5d} "
              f"data={rec.data_desc:20s} {shape}")
    print(f"proved in {bundle.prove_seconds():.1f}s, chain proof = "
          f"{bundle.size_fields()} field elements "
          f"({bundle.size_fields() * 4 / 1024:.1f} KB)")

    verifier = ZKGraphSession.verifier(owner.commitments, cfg)
    ok = verifier.verify(bundle)
    print(f"chain verifies: {ok}")
    assert ok
    print(f"result (persons named {name}, 3 hops of person 2): "
          f"{sorted(set(bundle.result['persons'].tolist()))}")

    # the session keygen cache: proving the same query again reuses every key
    before = dict(owner.cache.stats())
    owner.prove("IC1", params)
    after = owner.cache.stats()
    print(f"keygen cache: {before} -> {after} "
          f"(second prove added {after['misses'] - before['misses']} keygens)")


if __name__ == "__main__":
    main()
