"""IC1-style chained query proof: 3-hop friend expansion + name filter +
order-by — the expansion-centric decomposition end to end (paper §III-D).

    PYTHONPATH=src python examples/ldbc_ic1.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import prover as pv
from repro.core import planner
from repro.graphdb import ldbc

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)


def main():
    db = ldbc.generate(n_knows=150, n_persons=32, seed=13)
    commitments = planner.publish_commitments(db, CFG)
    name = int(db.node_props["person"]["firstName"][0])
    run = planner.plan_query(db, "IC1", dict(person=2, firstName=name))
    print(f"IC1 plan: {len(run.steps)} chained operator proofs:")
    for st in run.steps:
        c = st.op.circuit
        print(f"  {st.op.name:16s} rows={c.n_rows:5d} advice={c.n_advice:3d} "
              f"buses={len(c.buses)} gates={len(c.gates)} data={st.data_desc}")
    proofs = planner.prove_query(run, CFG)
    total_prove = sum(p.timings["total"] for p in proofs)
    total_size = sum(p.size_fields() for p in proofs)
    print(f"proved in {total_prove:.1f}s, chain proof = {total_size} field "
          f"elements ({total_size*4/1024:.1f} KB)")
    ok = planner.verify_query(run, proofs, commitments, CFG)
    print(f"chain verifies: {ok}")
    assert ok
    print(f"result (persons named {name}, 3 hops of person 2): "
          f"{sorted(set(run.result['persons'].tolist()))}")


if __name__ == "__main__":
    main()
