"""Quickstart: prove one LDBC query over a private graph via the session API.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full workflow (§III-C) through the three layers
(ir -> operator registry -> session, see docs/architecture.md): the owner
commits the dataset, proves a query as a chained bundle of operator proofs,
the verifier — holding only the published commitments — checks it; then a
tampered result is shown to be rejected.
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import prover as pv
from repro.core.operators import registry
from repro.core.session import ProofBundle, ZKGraphSession
from repro.graphdb import engine, ldbc

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)


def main(n_knows=200, n_persons=32, cfg=CFG, seed=7):
    # ---- data owner: private social graph + published commitments ---------
    db = ldbc.generate(n_knows=n_knows, n_persons=n_persons, seed=seed)
    t = db.tables["person_knows_person"]
    print(f"private graph: {db.n_nodes} persons, {len(t)} friendships")

    owner = ZKGraphSession(db, cfg)
    commitments = owner.commitments
    print(f"published {len(commitments)} dataset commitments")

    # ---- verifier asks: who are the friends of this person? ---------------
    src_id = int(t.src[0])   # guaranteed to have edges
    bundle = owner.prove("IS3", dict(person=src_id))
    friends = bundle.result["friends"]
    print(f"claimed friends of {src_id} (newest first): {friends.tolist()}")
    print(f"chain: {len(bundle.steps)} operator proofs, "
          f"{bundle.size_fields()} field elements "
          f"({bundle.size_fields() * 4 / 1024:.1f} KB), "
          f"prover {bundle.prove_seconds():.1f}s")

    # ---- verifier: only the commitments + the (serialized) bundle ---------
    # bytes cross the trust boundary through the canonical wire codec
    # (repro.core.wire): versioned, deterministic, bounded — never pickle
    verifier = ZKGraphSession.verifier(commitments, cfg)
    raw = bundle.to_bytes()
    received = ProofBundle.from_bytes(raw)
    assert received.to_bytes() == raw      # one canonical encoding
    ok = verifier.verify(received)
    print(f"verifier accepts: {ok}")
    assert ok
    # hostile bytes fail closed: no crash, no code execution, just False
    assert not verifier.verify_bytes(raw[: len(raw) // 2])
    assert not verifier.verify_bytes(b"\x80\x04pickle?")
    print("malformed / legacy-pickle bytes rejected: True")
    want, *_ = engine.expand_undirected(t, src_id)
    assert sorted(friends.tolist()) == sorted(want.tolist())

    # ---- a cheating prover: claim one extra 'friend' ----------------------
    bad = ProofBundle.from_bytes(bundle.to_bytes())
    rec = bad.steps[0]
    op = registry.build_operator(rec.kind, rec.shape)
    sel = np.nonzero(rec.instance[op.handles["out_sel"].index] == 1)[0]
    row = int(sel[0]) if len(sel) else 0
    rec.instance[op.handles["C_t"].index, row] = 999
    rejected = not verifier.verify(bad)
    print(f"tampered chain rejected: {rejected}")
    assert rejected
    print("quickstart OK")


if __name__ == "__main__":
    main()
