"""Quickstart: prove one single-source expansion over a private graph.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full workflow (§III-C): the owner commits the dataset, the
verifier submits a query, the owner proves, the verifier checks — then a
tampered result is shown to be rejected.
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import prover as pv
from repro.core import planner
from repro.core.operators import expansion
from repro.graphdb import engine, ldbc
from repro.graphdb.storage import pad_pow2

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)


def main():
    # ---- data owner: private social graph + published commitment ---------
    db = ldbc.generate(n_knows=200, n_persons=32, seed=7)
    t = db.tables["person_knows_person"]
    print(f"private graph: {db.n_nodes} persons, {len(t)} friendships")

    n_rows = pad_pow2(len(t))
    op = expansion.build_edge_list(n_rows, len(t)).keygen(CFG)
    cols = np.stack([t.src, t.dst])
    published_root = planner.data_root(cols, n_rows, CFG)
    print(f"published dataset commitment: {published_root[:4]}...")

    # ---- verifier asks: who are the friends of this person? ---------------
    src_id = int(t.src[0])   # guaranteed to have outgoing edges
    advice, instance, data = expansion.witness_edge_list(op, t.src, t.dst,
                                                         src_id)
    proof = op.prove(advice, instance, data)
    out_sel = instance[op.handles["out_sel"].index] == 1
    friends = instance[op.handles["C_t"].index][out_sel]
    print(f"claimed friends of {src_id}: {sorted(friends.tolist())}")
    print(f"proof size: {proof.size_fields()} field elements "
          f"({proof.size_fields() * 4 / 1024:.1f} KB), "
          f"prover {proof.timings['total']:.1f}s")

    ok = op.verify(instance, proof, expected_data_root=published_root)
    print(f"verifier accepts: {ok}")
    assert ok
    want, _ = engine.expand(t, src_id)
    assert sorted(friends.tolist()) == sorted(want.tolist())

    # ---- a cheating prover: claim one extra 'friend' ----------------------
    bad = instance.copy()
    row = int(np.nonzero(out_sel)[0][0])
    bad[op.handles["C_t"].index, row] = 999
    bad_proof = op.prove(advice, bad, data)
    print(f"tampered result rejected: {not op.verify(bad, bad_proof, published_root)}")
    assert not op.verify(bad, bad_proof, published_root)
    print("quickstart OK")


if __name__ == "__main__":
    main()
