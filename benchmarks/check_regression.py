"""Bench-regression gate: diff fresh BENCH_*.json against committed baselines.

Usage (from the repo root, after ``python -m benchmarks.run --only wire,kernels``)::

    python benchmarks/check_regression.py              # gate at 2x
    python benchmarks/check_regression.py --threshold 3
    python benchmarks/check_regression.py --update     # rewrite baselines

Every numeric leaf whose key ends in ``_us`` (microsecond timings) is
compared; a metric fails only if it is BOTH

* more than ``--threshold`` (default 2.0) times its committed baseline, AND
* more than ``--floor`` microseconds absolute (default 500us) above it —

so sub-millisecond jitter on trivially fast paths can never trip the gate
(CI-noise tolerance).  Size/count leaves (``*_bytes``, ``rows``, ...) are
never gated.

Overrides (documented in docs/architecture.md):

* ``ZKGRAPH_BENCH_ALLOW_REGRESSION=1`` turns failures into warnings — use
  when a PR knowingly trades one path's speed for another's (say so in the
  PR description).
* ``--update`` rewrites the committed baselines from the fresh run — use
  after an intentional perf change, and commit the result.

Baselines live in ``benchmarks/baselines/`` under the emitter's short name
(``wire.json``, ``kernels.json``) so the repo-root ``BENCH_*.json``
gitignore pattern never swallows them.  Missing fresh files or baselines
are reported but do not fail the gate (new emitters land before their
first baseline); missing *metrics* inside a present pair do not fail
either (emitters may grow).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE_DIR = ROOT / "benchmarks" / "baselines"
PAIRS = {                      # fresh (repo root) -> committed baseline
    "BENCH_wire.json": "wire.json",
    "BENCH_kernels.json": "kernels.json",
    "BENCH_transparency.json": "transparency.json",
    "BENCH_serving.json": "serving.json",
}
ALLOW_ENV = "ZKGRAPH_BENCH_ALLOW_REGRESSION"


def timing_leaves(node, prefix=""):
    """Flatten to {dotted.path: value} keeping only *_us numeric leaves."""
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(timing_leaves(v, f"{prefix}.{k}" if prefix else k))
    elif isinstance(node, (int, float)) and prefix.rsplit(".", 1)[-1] \
            .endswith("_us"):
        out[prefix] = float(node)
    return out


def compare(fresh: dict, base: dict, threshold: float, floor: float):
    """Yield (path, base_us, fresh_us, ratio) for every gated regression."""
    base_leaves = timing_leaves(base)
    for path, now in timing_leaves(fresh).items():
        ref = base_leaves.get(path)
        if ref is None:
            continue                       # new metric: no baseline yet
        if now > ref * threshold and now - ref > floor:
            yield (path, ref, now, now / ref if ref else float("inf"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when fresh > threshold * baseline (default 2)")
    ap.add_argument("--floor", type=float, default=500.0,
                    help="ignore regressions smaller than this many us")
    ap.add_argument("--update", action="store_true",
                    help="rewrite committed baselines from the fresh run")
    args = ap.parse_args()

    if args.update:
        BASELINE_DIR.mkdir(parents=True, exist_ok=True)
        for fresh_name, base_name in PAIRS.items():
            src = ROOT / fresh_name
            if src.exists():
                shutil.copy(src, BASELINE_DIR / base_name)
                print(f"baseline updated: benchmarks/baselines/{base_name}")
            else:
                print(f"skip (not emitted): {fresh_name}")
        return 0

    regressions, checked = [], 0
    for fresh_name, base_name in PAIRS.items():
        fresh_path = ROOT / fresh_name
        base_path = BASELINE_DIR / base_name
        if not fresh_path.exists():
            print(f"note: {fresh_name} not emitted this run — skipped")
            continue
        if not base_path.exists():
            print(f"note: no committed baseline {base_name} — skipped "
                  f"(run with --update to create it)")
            continue
        fresh = json.loads(fresh_path.read_text())
        base = json.loads(base_path.read_text())
        pair_regs = list(compare(fresh, base, args.threshold, args.floor))
        checked += len(timing_leaves(fresh))
        for path, ref, now, ratio in pair_regs:
            regressions.append((fresh_name, path, ref, now, ratio))

    print(f"checked {checked} timing metrics at threshold "
          f"{args.threshold}x / floor {args.floor}us")
    if not regressions:
        print("bench-regression gate: OK")
        return 0
    print("\nREGRESSIONS (fresh vs committed baseline):")
    for fname, path, ref, now, ratio in sorted(regressions,
                                               key=lambda r: -r[4]):
        print(f"  {fname}:{path}  {ref:.0f}us -> {now:.0f}us  "
              f"({ratio:.1f}x)")
    if os.environ.get(ALLOW_ENV) == "1":
        print(f"\n{ALLOW_ENV}=1 set: reporting only, not failing the gate")
        return 0
    print(f"\nIf intentional: re-baseline with "
          f"`python benchmarks/check_regression.py --update` and commit, "
          f"or set {ALLOW_ENV}=1 for this run.")
    return 1


if __name__ == "__main__":
    sys.exit(main())
