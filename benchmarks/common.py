"""Shared benchmark helpers: timing, analytic prover-memory model, the
in-circuit BFS strawman (the paper's 'naive' baseline in Fig 6a), CSV rows.

Scale note: the paper ran 60k/120k/180k-row fact tables on a 256 GB server;
this container benchmarks the same circuits at 2^11..2^14 rows — all
COMPARATIVE claims (edge-list vs CSR, flat-vs-linear scaling, BiRC vs
preprocess) are scale-free and reproduce directly; absolute times differ.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import field as F
from repro.core import plonkish as pk
from repro.core import prover as pv
from repro.core import verifier as vf
from repro.core.operators.common import Operator, eq_flag_gadget, fill_eq_flag
from repro.graphdb import ldbc

BENCH_CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=32)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6  # us


def est_prover_mem_bytes(circuit: pk.Circuit, cfg: pv.ProverConfig) -> int:
    """Analytic prover working set: LDEs + Merkle layers + ext columns.

    (jax device buffers are invisible to tracemalloc, so the comparative
    memory numbers use this model — dominated by (cols x N x blowup) u32.)
    """
    n, b = circuit.n_rows, cfg.blowup
    base_cols = (circuit.n_fixed + circuit.n_advice + circuit.n_instance +
                 circuit.n_data)
    ext_cols = circuit.n_ext * 4 + 4 * b   # helper + quotient components
    lde = (base_cols + ext_cols) * n * b * 4
    merkle = 3 * (2 * n * b * 8 * 4)       # digest layers per tree
    witness = base_cols * n * 4
    deep = n * b * 4 * 4 * 2
    return lde + merkle + witness + deep


def db_with_rows(n_rows: int, seed: int = 0):
    """LDBC-ish instance whose fact tables have ~n_rows rows."""
    return ldbc.generate(n_knows=n_rows, n_persons=max(24, n_rows // 16),
                         n_comments=n_rows, seed=seed)


# ---------------------------------------------------------------------------
# the 'naive in-circuit BFS' strawman (Fig 6a baseline)
# ---------------------------------------------------------------------------
def build_bfs_circuit(n_rows: int, m_edges: int, n_nodes: int, hops: int):
    """Executes BFS *inside* the circuit, hop by hop: per hop an edge
    activation lookup, a logUp in-degree aggregation, and an OR gate. Circuit
    size grows linearly with hop count — the paper's Fig 6a behaviour."""
    from repro.core.operators.common import region_selector
    c = pk.Circuit(n_rows, name=f"bfs{hops}")
    U = c.add_data("U")
    V = c.add_data("V")
    N = c.add_data("N")
    sel_e = region_selector(c, "sel_edge", m_edges)
    sel_n = region_selector(c, "sel_node", n_nodes)
    id_s = c.add_instance("id_s")
    f_prev, inv0 = eq_flag_gadget(c, "f0", N, id_s, sel_n)
    gadgets = [("f0", f_prev, inv0)]
    for k in range(hops):
        a_k = c.add_advice(f"a{k}")       # edge activation = f_k[U[e]]
        cnt = c.add_advice(f"cnt{k}")     # in-degree count of active edges
        nz = c.add_advice(f"nz{k}")
        inv = c.add_advice(f"nzinv{k}")
        f_next = c.add_advice(f"f{k+1}")
        c.add_bus(f"act{k}", [U, a_k], [N, f_prev], m_f=sel_e, t_sel=sel_n)
        c.add_bus(f"agg{k}", [V], [N], m_f=a_k, m_t=cnt, t_sel=sel_n)
        c.add_gate(f"nz_bool{k}", nz * (pk.Const(1) - nz))
        c.add_gate(f"nz_zero{k}", (pk.Const(1) - nz) * cnt)
        c.add_gate(f"nz_nonzero{k}", sel_n * nz * (cnt * inv - pk.Const(1)))
        c.add_gate(f"or{k}", sel_n * (f_next - (f_prev + nz - f_prev * nz)))
        c.add_gate(f"f_region{k}", (pk.Const(1) - sel_n) * f_next)
        gadgets.append((f"hop{k}", a_k, cnt, nz, inv, f_next))
        f_prev = f_next
    op = Operator(c.name, c)
    op.handles = dict(U=U, V=V, N=N, sel_e=sel_e, sel_n=sel_n, id_s=id_s,
                      hops=hops, m_edges=m_edges, n_nodes=n_nodes)
    return op


def bfs_witness(op, src, dst, node_ids, id_s):
    from repro.core.operators.common import host_inv
    c = op.circuit
    h = op.handles
    n = c.n_rows
    m, nn = h["m_edges"], h["n_nodes"]
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    node_ids = np.asarray(node_ids, np.int64)
    data[0, :m] = src % F.P
    data[1, :m] = dst % F.P
    data[2, :nn] = node_ids % F.P
    inst[0] = id_s
    sel_n = np.zeros(n, np.int64)
    sel_n[:nn] = 1
    sel_e = np.zeros(n, np.int64)
    sel_e[:m] = 1
    idx_of = {int(v): i for i, v in enumerate(node_ids.tolist())}
    f = (node_ids == id_s).astype(np.int64)
    # fill f0 eq gadget
    fl_idx = c.advice_names.index("f0/fl")
    inv_idx = c.advice_names.index("f0/inv")
    advice[fl_idx, :nn] = f
    diff = (data[2].astype(np.int64) - id_s) % F.P
    invv = host_inv(diff)
    advice[inv_idx] = np.where((sel_n == 1) & (advice[fl_idx] == 0), invv, 0)
    f_prev = np.zeros(n, np.int64)
    f_prev[:nn] = f
    for k in range(h["hops"]):
        a = np.zeros(n, np.int64)
        a[:m] = f_prev[[idx_of[int(u)] for u in src]]
        cnt = np.zeros(n, np.int64)
        for e in range(m):
            if a[e]:
                cnt[idx_of[int(dst[e])]] += 1
        nz = (cnt > 0).astype(np.int64)
        inv = host_inv(cnt % F.P)
        f_next = np.zeros(n, np.int64)
        f_next[:nn] = f_prev[:nn] | nz[:nn]
        advice[c.advice_names.index(f"a{k}")] = a
        advice[c.advice_names.index(f"cnt{k}")] = cnt
        advice[c.advice_names.index(f"nz{k}")] = nz
        advice[c.advice_names.index(f"nzinv{k}")] = np.where(nz == 1, inv, 0)
        advice[c.advice_names.index(f"f{k+1}")] = f_next
        f_prev = f_next
    return advice, inst, data
