"""One benchmark function per paper table/figure. Each yields CSV rows
(name, us_per_call, derived)."""
from __future__ import annotations

import numpy as np

from repro.core import prover as pv
from repro.core.session import ZKGraphSession
from repro.core.operators import birc, expansion, set_expansion, sssp
from repro.graphdb import engine
from repro.graphdb.storage import expand_bidirectional, pad_pow2

from . import common
from .common import BENCH_CFG, db_with_rows, est_prover_mem_bytes, timed


def timed_prove(op, a, i, d):
    """Prove twice, time the second run (jit caches warm — the steady-state
    cost a proving service pays; see EXPERIMENTS.md for methodology)."""
    op.prove(a.copy(), i, d)
    return timed(op.prove, a, i, d)


# ---------------------------------------------------------------------------
# Table I: edge-list vs CSR single-source expansion
# ---------------------------------------------------------------------------
def table1(rows: int = 2048):
    db = db_with_rows(rows)
    t = db.tables["person_knows_person"]
    src_id = int(t.src[0])
    n_rows = pad_pow2(len(t))
    # edge-list
    op_el = expansion.build_edge_list(n_rows, len(t))
    _, keygen_el = timed(op_el.keygen, BENCH_CFG)
    a, i, d = expansion.witness_edge_list(op_el, t.src, t.dst, src_id)
    op_el.prove(a.copy(), i, d)                  # warm jit caches
    proof_el, prove_el = timed(op_el.prove, a, i, d)
    op_el.verify(i, proof_el)
    ok, verify_el = timed(op_el.verify, i, proof_el)
    assert ok
    yield ("table1/edge_list/keygen", keygen_el, "")
    yield ("table1/edge_list/prove", prove_el, f"cols={op_el.circuit.n_advice}")
    yield ("table1/edge_list/verify", verify_el,
           f"proof_fields={proof_el.size_fields()}")
    # CSR
    col, row_ptr, lut = t.to_csr(db.node_ids)
    n_rows_c = pad_pow2(max(len(col), len(lut) + 1))
    op_csr = expansion.build_csr(n_rows_c, len(col), len(lut),
                                 id_bits=max(db.id_bits,
                                             n_rows_c.bit_length()))
    _, keygen_c = timed(op_csr.keygen, BENCH_CFG)
    a, i, d = expansion.witness_csr(op_csr, col, row_ptr, lut, src_id)
    op_csr.prove(a.copy(), i, d)                 # warm jit caches
    proof_c, prove_c = timed(op_csr.prove, a, i, d)
    op_csr.verify(i, proof_c)
    ok, verify_c = timed(op_csr.verify, i, proof_c)
    assert ok
    yield ("table1/csr/keygen", keygen_c, "")
    yield ("table1/csr/prove", prove_c, f"cols={op_csr.circuit.n_advice}")
    yield ("table1/csr/verify", verify_c,
           f"proof_fields={proof_c.size_fields()}")
    yield ("table1/ratio/prove_csr_over_el", prove_c / prove_el,
           "paper: 40.36/11.42=3.5x")


# ---------------------------------------------------------------------------
# Table II: public-parameter setup vs max rows
# ---------------------------------------------------------------------------
def table2():
    """Setup = twiddle/LDE/tree precompute capacity; measure keygen of a
    fixed-shape circuit at growing row counts (the paper's SRS-size axis)."""
    import repro.core.poly as poly
    for log_n in (10, 11, 12, 13, 14):
        n = 1 << log_n
        c = _fixed_circuit(n)
        pv.keygen(c, BENCH_CFG)          # warm the per-size NTT jit cache
        (keys), t_us = timed(pv.keygen, c, BENCH_CFG)
        yield (f"table2/setup_rows_2^{log_n}", t_us,
               f"lde_bytes={keys.fixed_lde.size * 4}")


def _fixed_circuit(n):
    from repro.core import plonkish as pk
    c = pk.Circuit(n, name=f"setup{n}")
    for j in range(8):
        c.add_fixed(f"f{j}", np.arange(n) * (j + 1))
    a = c.add_advice("a")
    c.add_gate("g", a * (a - pk.Const(1)))
    return c


# ---------------------------------------------------------------------------
# Table III: PK/VK generation per LDBC query
# ---------------------------------------------------------------------------
def table3(rows: int = 1024):
    db = db_with_rows(rows)
    session = ZKGraphSession(db, BENCH_CFG)
    params = {"IS3": dict(person=3), "IS4": dict(message=(1 << 20) + 5),
              "IS5": dict(message=(1 << 20) + 7),
              "IC1": dict(person=2, firstName=int(
                  db.node_props["person"]["firstName"][0])),
              "IC2": dict(person=4, k=10), "IC8": dict(person=5, k=10),
              "IC13": dict(person1=1, person2=9)}
    for q, p in params.items():
        run = session.run_query(q, p)

        def keygen_all():
            for st in run.steps:
                st.op.keygen(BENCH_CFG)     # raw keygen, no session cache
        _, t_us = timed(keygen_all)
        yield (f"table3/keygen/{q}", t_us, f"steps={len(run.steps)}")


# ---------------------------------------------------------------------------
# keygen cache: cold vs warm session (the ZKGraphSession hot-path win)
# ---------------------------------------------------------------------------
def cachewin(rows: int = 1024):
    """Before/after for the session keygen cache on repeated queries: a warm
    session skips every per-step keygen (fixed-column intt + LDE + device
    transfer), which the seed paid on each prove_query call."""
    from repro.core.operators import registry
    from repro.core.session import circuit_shape_digest
    db = db_with_rows(rows)
    p = dict(person=3)
    ZKGraphSession(db, BENCH_CFG).prove("IS3", p)       # warm jit caches
    session = ZKGraphSession(db, BENCH_CFG)
    _, cold_us = timed(session.prove, "IS3", p)         # cold keygen cache
    after_cold = session.cache.stats()
    _, warm_us = timed(session.prove, "IS3", p)         # warm keygen cache
    after_warm = session.cache.stats()
    yield ("cachewin/IS3/cold_session", cold_us,
           f"keygens={after_cold['misses']}")
    yield ("cachewin/IS3/warm_session", warm_us,
           f"keygen_hits={after_warm['hits']};"
           f"speedup={cold_us / warm_us:.2f}x")
    # the shape digest is memoized on the circuit: a cache *hit* no longer
    # pays the SHA-256 over every fixed-column's bytes on each ensure()
    t = db.tables["person_knows_person"]
    op = registry.build_operator("expand", dict(
        n_rows=pad_pow2(len(t)), m_edges=len(t), with_prop=False,
        reverse=False))
    session.cache.ensure(op, BENCH_CFG)                 # digest + keygen once
    _, hit_us = timed(session.cache.ensure, op, BENCH_CFG)  # memoized digest
    op.circuit._shape_digest = None                     # force a recompute
    _, digest_us = timed(circuit_shape_digest, op.circuit)
    yield ("cachewin/ensure_hit_memoized", hit_us,
           f"rows={op.circuit.n_rows}")
    yield ("cachewin/ensure_hit_digest_recompute", hit_us + digest_us,
           f"digest_us={digest_us:.1f};"
           f"speedup={(hit_us + digest_us) / max(hit_us, 1e-9):.2f}x")


# ---------------------------------------------------------------------------
# Fig 6a: SSSP operator vs in-circuit BFS, varying hops
# ---------------------------------------------------------------------------
def fig6a(rows: int = 512):
    db = db_with_rows(rows)
    t = db.tables["person_knows_person"]
    src_id = int(db.node_ids[0])
    n_rows = pad_pow2(max(len(t), db.n_nodes))
    # our SSSP: hop-independent
    dist, pred, pd = engine.bfs_sssp(t, db.node_ids, src_id, True)
    op = sssp.build(n_rows, len(t), db.n_nodes, undirected=True)
    op.keygen(BENCH_CFG)
    a, i, d = sssp.witness(op, t.src, t.dst, db.node_ids, src_id, dist,
                           pred, pd)
    proof, t_sssp = timed_prove(op, a, i, d)
    mem = est_prover_mem_bytes(op.circuit, BENCH_CFG)
    yield ("fig6a/sssp/anyhops", t_sssp, f"mem_bytes={mem}")
    for hops in (2, 4, 6):
        bop = common.build_bfs_circuit(n_rows, len(t), db.n_nodes, hops)
        bop.keygen(BENCH_CFG)
        a, i, d = common.bfs_witness(bop, t.src, t.dst, db.node_ids, src_id)
        proof, t_bfs = timed_prove(bop, a, i, d)
        mem_b = est_prover_mem_bytes(bop.circuit, BENCH_CFG)
        yield (f"fig6a/bfs/hops{hops}", t_bfs,
               f"mem_bytes={mem_b};ratio={t_bfs/t_sssp:.2f}")


# ---------------------------------------------------------------------------
# Fig 6b: set-based expansion vs repeated single-source
# ---------------------------------------------------------------------------
def fig6b(rows: int = 2048):
    db = db_with_rows(rows)
    t = db.tables["person_knows_person"]
    n_rows = pad_pow2(len(t))
    for n_start in (4, 16, 64):
        ids = np.unique(t.src)[:n_start]
        op = set_expansion.build(pad_pow2(max(len(t), len(ids) + 2)), len(t),
                                 len(ids))
        op.keygen(BENCH_CFG)
        a, i, d = set_expansion.witness(op, t.src, t.dst, ids)
        _, t_set = timed_prove(op, a, i, d)
        mem = est_prover_mem_bytes(op.circuit, BENCH_CFG)
        yield (f"fig6b/set_based/n{n_start}", t_set, f"mem_bytes={mem}")
        # repeated single-source: cost = n_start * (one expansion proof)
        op1 = expansion.build_edge_list(n_rows, len(t))
        op1.keygen(BENCH_CFG)
        a, i, d = expansion.witness_edge_list(op1, t.src, t.dst, int(ids[0]))
        _, t_one = timed_prove(op1, a, i, d)
        yield (f"fig6b/repeated_single/n{n_start}", t_one * n_start,
               f"mem_bytes={est_prover_mem_bytes(op1.circuit, BENCH_CFG) * n_start}"
               f";extrapolated_from_one")


# ---------------------------------------------------------------------------
# Table IV: BiRC integrated vs preprocessing (duplicate edges)
# ---------------------------------------------------------------------------
def table4(rows: int = 1024):
    db = db_with_rows(rows)
    t = db.tables["person_knows_person"]
    ids = np.unique(t.src)[:8]
    # set-based expansion: integrated BiRC on canonical storage
    op = set_expansion.build(pad_pow2(len(t)), len(t), len(ids),
                             bidirectional=True)
    op.keygen(BENCH_CFG)
    a, i, d = set_expansion.witness(op, t.src, t.dst, ids)
    _, t_birc = timed_prove(op, a, i, d)
    yield ("table4/set_exp/birc", t_birc,
           f"mem_bytes={est_prover_mem_bytes(op.circuit, BENCH_CFG)}")
    # preprocessing: duplicated edge table (2m rows), plain operator
    t2 = expand_bidirectional(t)
    op2 = set_expansion.build(pad_pow2(len(t2)), len(t2), len(ids))
    op2.keygen(BENCH_CFG)
    a, i, d = set_expansion.witness(op2, t2.src, t2.dst, ids)
    _, t_pre = timed_prove(op2, a, i, d)
    yield ("table4/set_exp/preprocess", t_pre,
           f"mem_bytes={est_prover_mem_bytes(op2.circuit, BENCH_CFG)}"
           f";ratio={t_pre/t_birc:.2f} (paper 21.67/8.22=2.6x)")
    # SSSP variant
    src_id = int(db.node_ids[0])
    dist, pred, pd = engine.bfs_sssp(t, db.node_ids, src_id, True)
    n_rows = pad_pow2(max(len(t), db.n_nodes))
    op3 = sssp.build(n_rows, len(t), db.n_nodes, undirected=True)
    op3.keygen(BENCH_CFG)
    a, i, d = sssp.witness(op3, t.src, t.dst, db.node_ids, src_id, dist,
                           pred, pd)
    _, t_birc_s = timed_prove(op3, a, i, d)
    yield ("table4/sssp/birc", t_birc_s,
           f"mem_bytes={est_prover_mem_bytes(op3.circuit, BENCH_CFG)}")
    n_rows2 = pad_pow2(max(len(t2), db.n_nodes))
    op4 = sssp.build(n_rows2, len(t2), db.n_nodes, undirected=False)
    op4.keygen(BENCH_CFG)
    dist2, pred2, pd2 = engine.bfs_sssp(t2, db.node_ids, src_id, False)
    a, i, d = sssp.witness(op4, t2.src, t2.dst, db.node_ids, src_id, dist2,
                           pred2, pd2)
    _, t_pre_s = timed_prove(op4, a, i, d)
    yield ("table4/sssp/preprocess", t_pre_s,
           f"mem_bytes={est_prover_mem_bytes(op4.circuit, BENCH_CFG)}"
           f";ratio={t_pre_s/t_birc_s:.2f} (paper 31.31/26.96=1.16x)")


# ---------------------------------------------------------------------------
# Fig 7: proof-generation breakdown for IC1 and IC9
# ---------------------------------------------------------------------------
def fig7(rows: int = 1024):
    db = db_with_rows(rows)
    session = ZKGraphSession(db, BENCH_CFG)
    for q, p in (("IC1", dict(person=2, firstName=int(
            db.node_props["person"]["firstName"][0]))),
            ("IC9", dict(person=6, k=10))):
        bundle = session.prove(q, p)
        total = 0.0
        for rec in bundle.steps:
            t_us = rec.proof.timings["total"] * 1e6
            total += t_us
            yield (f"fig7/{q}/{rec.kind}", t_us,
                   ";".join(f"{k}={v:.2f}s"
                            for k, v in rec.proof.timings.items()
                            if k != "total"))
        yield (f"fig7/{q}/TOTAL", total, f"steps={len(bundle.steps)}")


# ---------------------------------------------------------------------------
# wire codec: canonical ProofBundle bytes vs the seed's pickle placeholder
# ---------------------------------------------------------------------------
def wire_codec(rows: int = 1024):
    """Encode/decode time + serialized size for the canonical wire format
    (repro.core.wire) against the legacy pickle it replaced (pickle is
    measured here as the baseline only — it no longer ships).  Also emits
    ``BENCH_wire.json`` so the serialization perf trajectory is recorded."""
    import json
    import pickle

    from repro.core.session import ProofBundle

    db = db_with_rows(rows)
    session = ZKGraphSession(db, BENCH_CFG)
    records = {}
    for q, p in (("IS5", dict(message=(1 << 20) + 7)),
                 ("IS3", dict(person=3)),
                 ("IC13", dict(person1=1, person2=9))):
        bundle = session.prove(q, p)
        raw, enc_us = timed(bundle.to_bytes)
        rt, dec_us = timed(ProofBundle.from_bytes, raw)
        assert rt.to_bytes() == raw                 # canonical round trip
        pkl, penc_us = timed(pickle.dumps, bundle, pickle.HIGHEST_PROTOCOL)
        _, pdec_us = timed(pickle.loads, pkl)
        records[q] = dict(
            steps=len(bundle.steps), wire_bytes=len(raw),
            pickle_bytes=len(pkl), encode_us=round(enc_us, 1),
            decode_us=round(dec_us, 1), pickle_encode_us=round(penc_us, 1),
            pickle_decode_us=round(pdec_us, 1),
            size_ratio=round(len(raw) / len(pkl), 3))
        yield (f"wire/{q}/encode", enc_us,
               f"bytes={len(raw)};pickle_bytes={len(pkl)};"
               f"size_ratio={len(raw) / len(pkl):.2f}")
        yield (f"wire/{q}/decode", dec_us,
               f"pickle_decode_us={pdec_us:.1f}")
    with open("BENCH_wire.json", "w") as f:
        json.dump(dict(rows=rows, cfg=dict(
            blowup=BENCH_CFG.blowup, n_queries=BENCH_CFG.n_queries,
            fri_final_size=BENCH_CFG.fri_final_size), queries=records),
            f, indent=2, sort_keys=True)
    yield ("wire/BENCH_wire.json", 0.0, f"queries={len(records)}")


# ---------------------------------------------------------------------------
# transparency: manifest codec + digest + log append / proof timings
# ---------------------------------------------------------------------------
def transparency_bench(rows: int = 1024):
    """Perf trajectory of the publication path (repro.core.transparency):
    canonical manifest encode/decode/digest, transparency-log appends at
    growing log sizes, and inclusion/consistency proof generate+verify.
    Emits ``BENCH_transparency.json``."""
    import json

    from repro.core.commit import CommitmentManifest
    from repro.core import transparency as tl

    db = db_with_rows(rows)
    session = ZKGraphSession(db, BENCH_CFG)
    manifest = session.commitments
    raw, enc_us = timed(manifest.to_bytes)
    m2, dec_us = timed(CommitmentManifest.from_bytes, raw)
    assert m2.to_bytes() == raw                     # canonical round trip
    tl.manifest_digest(raw)                         # warm the sponge jit
    digest, dig_us = timed(tl.manifest_digest, raw)
    records = dict(manifest_bytes=len(raw), encode_us=round(enc_us, 1),
                   decode_us=round(dec_us, 1), digest_us=round(dig_us, 1))
    yield ("transparency/manifest/encode", enc_us, f"bytes={len(raw)}")
    yield ("transparency/manifest/decode", dec_us, "")
    yield ("transparency/manifest/digest", dig_us,
           f"roots={len(manifest.roots)}")

    # append cost vs log size: O(log n) compressions thanks to subtree memo
    log = tl.TransparencyLog("bench-log")
    appends = {}
    next_mark = 1
    for i in range(64):
        entry = raw + i.to_bytes(8, "little")       # 64 manifest revisions
        if i + 1 == next_mark:
            cp, t_us = timed(log.append, entry)
            appends[i + 1] = round(t_us, 1)
            yield (f"transparency/log/append_at_{i + 1}", t_us,
                   f"tree_size={cp.tree_size}")
            next_mark *= 2
        else:
            log.append(entry)
    records["append_us_by_size"] = appends

    cp = log.checkpoint()
    pf, inc_us = timed(log.inclusion_proof, 17)
    leaf = tl.manifest_digest(log.entry(17))
    ok, incv_us = timed(tl.verify_inclusion, cp, pf, leaf)
    assert ok
    yield ("transparency/inclusion/prove", inc_us,
           f"path_nodes={pf.path.shape[0]}")
    yield ("transparency/inclusion/verify", incv_us, "")
    old_cp = log.checkpoint(21)
    cpf, con_us = timed(log.consistency_proof, 21)
    ok, conv_us = timed(tl.verify_consistency, old_cp, cp, cpf)
    assert ok
    yield ("transparency/consistency/prove", con_us,
           f"path_nodes={cpf.path.shape[0]}")
    yield ("transparency/consistency/verify", conv_us, "")
    records.update(
        inclusion_prove_us=round(inc_us, 1),
        inclusion_verify_us=round(incv_us, 1),
        consistency_prove_us=round(con_us, 1),
        consistency_verify_us=round(conv_us, 1), log_size=log.size)

    # the durable store: fsync'd append, full replay-and-cross-check reopen
    import tempfile
    from pathlib import Path

    from repro.core import gossip as gp
    from repro.core.transparency import TransparencyLog

    with tempfile.TemporaryDirectory() as tmp:
        path = Path(tmp) / "bench.log"
        dlog = TransparencyLog.open(path, "bench-log")
        for i in range(32):
            dlog.append(raw + i.to_bytes(8, "little"))
        _, dapp_us = timed(dlog.append, raw + b"durable-append-timing")
        _, sync_us = timed(dlog.sync)
        dlog.close()
        dlog2, open_us = timed(TransparencyLog.open, path)
        store_bytes = path.stat().st_size
        n_leaves = dlog2.size
        dlog2.close()
    yield ("transparency/logstore/append", dapp_us,
           f"fsync;store_bytes={store_bytes}")
    yield ("transparency/logstore/sync", sync_us, "replay+cross-check")
    yield ("transparency/logstore/open_replay", open_us,
           f"leaves={n_leaves}")
    records.update(durable_append_us=round(dapp_us, 1),
                   durable_sync_us=round(sync_us, 1),
                   durable_open_replay_us=round(open_us, 1),
                   store_bytes=store_bytes)

    # gossip: Ed25519 sign/verify, emit, and the peer's verify-and-advance
    # hot path (all pure Python — the ed25519_* rows are the floor every
    # networked gossip round pays per head)
    from repro.core import ed25519 as ed
    key = ed.SigningKey.from_secret(b"bench-gossip-key")
    head = log.checkpoint()
    sig, sign_us = timed(gp.sign_checkpoint, key, head)
    ok, sigv_us = timed(gp.verify_signature, key.pub, head, sig)
    assert ok
    yield ("transparency/ed25519/sign", sign_us,
           f"msg_bytes={len(head.to_bytes()) + 1}")
    yield ("transparency/ed25519/verify", sigv_us, "")
    msg, emit_us = timed(gp.emit, log, key, 21)
    wire_bytes = msg.to_bytes()
    cp21 = log.checkpoint(21)
    pinned_root = np.asarray(cp21.root, np.uint32)

    def offer_advance():
        # exactly the verifier's hot path: decode hostile bytes, check the
        # signature, verify the consistency proof, advance the pin.  The
        # peer's pre-pinned state is set directly so bootstrap cost (an
        # extra signature check + offer) stays out of the gated metric.
        p = gp.GossipPeer(log.origin, key.pub)
        p.head, p.seen = cp21, {21: pinned_root}
        return p.offer(gp.GossipMessage.from_bytes(wire_bytes))

    assert offer_advance() is True
    _, offer_us = timed(offer_advance)
    yield ("transparency/gossip/emit", emit_us,
           f"bytes={len(wire_bytes)}")
    yield ("transparency/gossip/decode_verify_advance", offer_us,
           f"span=21->{log.size}")
    records.update(gossip_emit_us=round(emit_us, 1),
                   gossip_offer_us=round(offer_us, 1),
                   gossip_bytes=len(wire_bytes),
                   ed25519_sign_us=round(sign_us, 1),
                   ed25519_verify_us=round(sigv_us, 1))

    # framed round trip: one gossip head served over the real socket
    # transport (loopback), REQ_HEAD -> signed envelope -> verify+advance
    from repro.net import framing, server as net_server
    from repro.net.peer import PeerClient

    srv = net_server.NetServer()
    srv.register(framing.REQ_HEAD,
                 lambda payload: (framing.RESP_HEAD, wire_bytes))
    with srv.serving() as addr:
        client = PeerClient(addr, timeout=5.0)

        def framed_round_trip():
            kind, payload = client.request(framing.REQ_HEAD, b"")
            assert kind == framing.RESP_HEAD
            p = gp.GossipPeer(log.origin, key.pub)
            p.head, p.seen = cp21, {21: pinned_root}
            return p.offer(gp.GossipMessage.from_bytes(payload))

        assert framed_round_trip() is True
        _, rt_us = timed(framed_round_trip)
        client.close()
    yield ("transparency/net/framed_head_round_trip", rt_us,
           f"loopback;bytes={len(wire_bytes)}")
    records.update(framed_head_round_trip_us=round(rt_us, 1))

    with open("BENCH_transparency.json", "w") as f:
        json.dump(dict(rows=rows, results=records), f, indent=2,
                  sort_keys=True)
    yield ("transparency/BENCH_transparency.json", 0.0, f"log_size={log.size}")


# ---------------------------------------------------------------------------
# compute backends: ref vs pallas-interpret vs pallas, per primitive + e2e
# ---------------------------------------------------------------------------
def kernels(rows: int = 256):
    """Per-primitive and end-to-end backend comparison; emits
    ``BENCH_kernels.json``.

    On a CPU container the compiled ``pallas`` backend is unavailable
    (recorded as such) and ``pallas-interpret`` is *slower* than ``ref`` —
    the interpreter exists for parity/CI, not speed; the speedup column is
    meaningful on accelerator hosts where ``pallas`` compiles.  All timings
    are second-call (warm jit caches)."""
    import dataclasses
    import json

    import jax
    import jax.numpy as jnp

    from repro.core import backend, field as F, hashing, merkle, poly

    usable, status = [], {}
    for name in backend.names():
        ok, reason = backend.probe(name)
        status[name] = "ok" if ok else reason
        if ok:
            usable.append(name)
        yield (f"kernels/backend/{name}", 0.0, status[name][:60])

    rng = np.random.default_rng(0)
    states = jnp.asarray(rng.integers(0, F.P, size=(4096, 16))
                         .astype(np.uint32))
    hrows = jnp.asarray(rng.integers(0, F.P, size=(1024, 8))
                        .astype(np.uint32))
    gp = jnp.asarray(rng.integers(0, F.P, size=(4096, 4)).astype(np.uint32))
    prims = {
        "poseidon_permute_4096": lambda: hashing.permute(states),
        "hash_rows_1024x8": lambda: hashing.hash_rows(hrows),
        "merkle_commit_1024x8": lambda: merkle.commit(hrows).root,
        "grand_product_ext_4096": lambda: backend.active()
                                          .grand_product_ext(gp),
    }
    for log_n in (10, 12, 14):
        x = jnp.asarray(rng.integers(0, F.P, size=(4, 1 << log_n))
                        .astype(np.uint32))
        prims[f"ntt_b4_2^{log_n}"] = (lambda x=x: poly.ntt(x))

    def run_blocked(fn):
        return jax.block_until_ready(fn())

    primitives = {}
    for pname, fn in prims.items():
        primitives[pname] = {}
        for bname in usable:
            with backend.use(bname):
                run_blocked(fn)                          # warm trace + jit
                _, t_us = timed(run_blocked, fn)
            primitives[pname][f"{bname}_us"] = round(t_us, 1)
        ref_us = primitives[pname]["ref_us"]
        derived = ";".join(f"{b}={primitives[pname][f'{b}_us']:.0f}us"
                           for b in usable)
        yield (f"kernels/{pname}", ref_us, derived)

    # end-to-end prove latency per LDBC query, per backend
    db = db_with_rows(rows)
    manifest = ZKGraphSession(db, BENCH_CFG).commitments   # shared: parity
    end_to_end = {}
    for q, p in (("IS3", dict(person=3)),
                 ("IS5", dict(message=(1 << 20) + 7))):
        end_to_end[q] = {}
        for bname in usable:
            cfg = dataclasses.replace(BENCH_CFG, backend=bname)
            session = ZKGraphSession(db, cfg, commitments=manifest)
            session.prove(q, p)                          # warm
            bundle, t_us = timed(session.prove, q, p)
            end_to_end[q][f"{bname}_us"] = round(t_us, 1)
        yield (f"kernels/e2e/{q}", end_to_end[q]["ref_us"],
               ";".join(f"{b}={end_to_end[q][f'{b}_us']:.0f}us"
                        for b in usable))

    with open("BENCH_kernels.json", "w") as f:
        json.dump(dict(rows=rows, backends=status, primitives=primitives,
                       end_to_end=end_to_end), f, indent=2, sort_keys=True)
    yield ("kernels/BENCH_kernels.json", 0.0,
           f"backends={'+'.join(usable)}")


# ---------------------------------------------------------------------------
# serving: ProofService throughput vs a sequential prove loop, same run
# ---------------------------------------------------------------------------
def serving(rows: int = 128):
    """Concurrent serving throughput (repro.serve.ProofService) against a
    sequential ``session.prove`` loop over the SAME query mix, measured in
    the same run with warm jit caches.  Lane-batched proving amortizes the
    per-dispatch overhead every solo prove pays, so queries/sec should grow
    with concurrency while each bundle stays wire-byte-identical to its
    solo prove (asserted below, timings aside).  Emits
    ``BENCH_serving.json``; latency leaves are gated by
    ``benchmarks/check_regression.py`` against baselines/serving.json."""
    import json
    import time

    from repro.core.session import ProofBundle
    from repro.serve import ProofService

    def strip_timings(raw: bytes) -> bytes:
        bundle = ProofBundle.from_bytes(raw)
        for sp in bundle.steps:
            sp.proof.timings = {}
        return bundle.to_bytes()

    db = db_with_rows(rows)
    session = ZKGraphSession(db, BENCH_CFG)
    queries = [("IS5", dict(message=(1 << 20) + 7 + i)) for i in range(16)]

    def serve(n):
        """Submit queries[:n] concurrently; max_batch=n + a long deadline
        means exactly one size-triggered flush per full batch, so the jit
        cache sees one lane count per concurrency level."""
        latencies = []
        t0 = time.perf_counter()
        with ProofService(session, max_batch=n, flush_interval=5.0) as svc:
            futs = []
            for q, p in queries[:n]:
                ts = time.perf_counter()
                fut = svc.submit(q, p)
                fut.add_done_callback(
                    lambda _f, ts=ts: latencies.append(
                        (time.perf_counter() - ts) * 1e6))
                futs.append(fut)
            bundles = [f.result() for f in futs]
            stats = svc.stats()
        total_us = (time.perf_counter() - t0) * 1e6
        return bundles, latencies, stats, total_us

    # warm every shape the measured runs will hit: the solo prover (c=1
    # degrades to it; also the sequential baseline) and each padded lane
    # count the service flushes at
    session.prove(*queries[0])
    for n in (4, 16):
        serve(n)

    results = {}
    for conc in (1, 4, 16):
        seq_bundles, seq_us = timed(
            lambda n=conc: [session.prove(q, p) for q, p in queries[:n]])
        bundles, lat, stats, svc_us = serve(conc)
        for got, want in zip(bundles, seq_bundles):
            assert strip_timings(got.to_bytes()) == \
                strip_timings(want.to_bytes()), \
                "serviced bundle bytes diverged from the sequential prover"
        qps = conc / (svc_us / 1e6)
        seq_qps = conc / (seq_us / 1e6)
        speedup = seq_us / svc_us
        occ = stats["batch_occupancy"]
        results[f"concurrency_{conc}"] = dict(
            queries=conc,
            service_total_us=round(svc_us, 1),
            sequential_total_us=round(seq_us, 1),
            qps=round(qps, 3), sequential_qps=round(seq_qps, 3),
            speedup=round(speedup, 3),
            latency_p50_us=round(float(np.percentile(lat, 50)), 1),
            latency_p95_us=round(float(np.percentile(lat, 95)), 1),
            occupancy_mean=round(occ["mean"], 2),
            batches=stats["counters"]["batches"],
            pad_lanes=stats["counters"]["pad_lanes"])
        yield (f"serving/c{conc}/service_total", svc_us,
               f"qps={qps:.2f};speedup={speedup:.2f}x;"
               f"occupancy={occ['mean']:.1f}")
        yield (f"serving/c{conc}/sequential_total", seq_us,
               f"qps={seq_qps:.2f}")
        yield (f"serving/c{conc}/latency_p95", float(np.percentile(lat, 95)),
               f"p50={np.percentile(lat, 50):.0f}us")

    with open("BENCH_serving.json", "w") as f:
        json.dump(dict(rows=rows, query="IS5", cfg=dict(
            blowup=BENCH_CFG.blowup, n_queries=BENCH_CFG.n_queries,
            fri_final_size=BENCH_CFG.fri_final_size), results=results),
            f, indent=2, sort_keys=True)
    yield ("serving/BENCH_serving.json", 0.0,
           f"speedup_c16={results['concurrency_16']['speedup']:.2f}x")


# ---------------------------------------------------------------------------
# Fig 8: scalability with database size
# ---------------------------------------------------------------------------
def fig8():
    for rows in (1024, 2048, 4096):
        db = db_with_rows(rows)
        session = ZKGraphSession(db, BENCH_CFG)
        verifier = ZKGraphSession.verifier(session.commitments, BENCH_CFG)
        for q, p in (("IS3", dict(person=3)),
                     ("IS5", dict(message=(1 << 20) + 7)),
                     ("IC13", dict(person1=1, person2=9))):
            bundle = session.prove(q, p)
            prove_us = bundle.prove_seconds() * 1e6
            ok, verify_us = timed(verifier.verify, bundle)
            assert ok
            yield (f"fig8/{q}/rows{rows}/prove", prove_us,
                   f"proof_fields={bundle.size_fields()}")
            yield (f"fig8/{q}/rows{rows}/verify", verify_us, "")


ALL = {"table1": table1, "table2": table2, "table3": table3, "fig6a": fig6a,
       "fig6b": fig6b, "table4": table4, "fig7": fig7, "fig8": fig8,
       "cachewin": cachewin, "wire": wire_codec,
       "transparency": transparency_bench, "kernels": kernels,
       "serving": serving}
