"""Roofline report generator: reads dryrun_single.json (+ dryrun_multi.json)
and emits the EXPERIMENTS.md §Roofline table.

    PYTHONPATH=src python -m benchmarks.roofline [--json dryrun_single.json]

Terms (per cell, hardware model: TPU v5e-like 197 TF/s bf16, 819 GB/s HBM,
50 GB/s/link ICI):
    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)
with HLO_* = per-device cost_analysis x chips and collective_bytes summed
from the partitioned module's collective ops. MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) for train; 2*N*D for single-token decode/prefill-token.
"""
from __future__ import annotations

import argparse
import json

from repro.configs.registry import SHAPES, get_config
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS


def model_flops(arch: str, shape_name: str) -> float:
    if arch.startswith("zkgraph"):
        return 0.0                      # no 6ND analogue for the prover
    cfg = get_config(arch)
    from repro.models.config import active_param_count
    n_active = active_param_count(cfg)
    s = SHAPES[shape_name]
    if s.kind == "train":
        tokens = s.seq_len * s.global_batch
        return 6.0 * n_active * tokens
    if s.kind == "prefill":
        tokens = s.seq_len * s.global_batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * s.global_batch      # decode: 1 token per request


def fmt_t(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def analyse(rec: dict) -> dict:
    chips = rec["n_chips"]
    c = rec.get("corrected")
    if c and "UNCORRECTED" not in c.get("method", ""):
        flops = c["flops"] * chips
        hbm = c["bytes"] * chips
        coll = c["coll"] * chips
    else:
        flops = rec["per_device_flops"] * chips
        hbm = rec["per_device_bytes"] * chips
        coll = rec["collectives"]["total"] * chips
    t_c = flops / (chips * PEAK_FLOPS)
    t_m = hbm / (chips * HBM_BW)
    t_x = coll / (chips * ICI_BW)
    mf = model_flops(rec["arch"], rec["shape"])
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    bound = max(t_c, t_m, t_x, 1e-30)
    # roofline fraction = useful-model-FLOP time / the binding term
    # (MFU-style: 1.0 would mean the dominant resource is fully spent on
    # model FLOPs)
    t_useful = mf / (chips * PEAK_FLOPS)
    return dict(t_compute=t_c, t_memory=t_m, t_collective=t_x,
                dominant=dom[0], model_flops=mf,
                useful_frac=mf / flops if flops else 0.0,
                roofline_frac=t_useful / bound)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_single.json")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = json.load(open(args.json))
    print("| arch | shape | compute | memory | collective | dominant | "
          "roofline frac | MODEL/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("ok") is None:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | "
                  f"{r['skipped'][:40]} |")
            continue
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | — | — | — | FAIL | — | |")
            continue
        a = analyse(r)
        note = "" if r.get("corrected") and "UNCORRECTED" not in \
            r["corrected"].get("method", "") else "raw†"
        if r["arch"].startswith("zkgraph"):
            note = "paper workload"
        print(f"| {r['arch']} | {r['shape']} | {fmt_t(a['t_compute'])} | "
              f"{fmt_t(a['t_memory'])} | {fmt_t(a['t_collective'])} | "
              f"**{a['dominant']}** | {a['roofline_frac']*100:.1f}% | "
              f"{a['useful_frac']*100:.0f}% {note} |")


if __name__ == "__main__":
    main()
