# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (e.g. table1,fig6a)")
    args = ap.parse_args()
    from . import paper_tables
    subset = args.only.split(",") if args.only else list(paper_tables.ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in subset:
        fn = paper_tables.ALL[name]
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
