"""Extract and execute the README quickstart code block.

The CI docs job runs this (``python docs/run_quickstart.py`` from the repo
root), so the snippet users copy-paste is executed verbatim on every push —
documentation that stops running fails the build instead of rotting.
"""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def main():
    readme = (ROOT / "README.md").read_text()
    match = re.search(r"```python\n(.*?)```", readme, re.S)
    if not match:
        sys.exit("README.md has no ```python quickstart block")
    code = match.group(1)
    sys.path.insert(0, str(ROOT / "src"))
    print("-- executing README quickstart --")
    exec(compile(code, "README.md#quickstart", "exec"), {"__name__": "readme"})
    print("-- README quickstart OK --")


if __name__ == "__main__":
    main()
